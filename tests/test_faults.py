"""Fault-injection plane + crash-safe recovery (repro.faults).

Host-side coverage: plan/retry semantics, the save_sharded crash matrix
(SIGKILL at every injection point -> latest_step never names a torn
dir), CRC quarantine with committed-history fallback, debris GC, and
the launcher's recovery-flag guards.  The multi-device ElasticDriver
kill matrix lives in test_fault_matrix.py.
"""
import errno
import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro import ckpt as ckpt_lib
from repro import faults as F
from repro.checkpoint import CorruptCheckpointError, committed_steps
from repro.faults import harness
from repro.faults.recovery import (RecoveryReport, restore_with_fallback,
                                   walk_committed)


def _tree():
    return {"w": np.arange(64, dtype=np.float32),
            "b": np.float32(2.0),
            "k": np.arange(6, dtype=np.int32).reshape(2, 3)}


def _save(base, step, tree=None, **kw):
    ckpt_lib.save_sharded(ckpt_lib.step_dir(base, step), step,
                          tree if tree is not None else _tree(), **kw)


def _dead_pid():
    """A pid guaranteed dead: a child that already exited."""
    out = subprocess.run(
        [sys.executable, "-c", "import os; print(os.getpid())"],
        capture_output=True, text=True, check=True)
    return int(out.stdout.strip())


# ---------------------------------------------------------------- plan

def test_plan_fires_on_nth_arrival_for_times_window():
    plan = F.FaultPlan([F.FaultSpec("p", "eio", hit=2, times=2)])
    with F.install(plan):
        F.maybe_fire("p")                      # arrival 1: clean
        for _ in range(2):                     # arrivals 2, 3: fault
            with pytest.raises(OSError) as ei:
                F.maybe_fire("p")
            assert ei.value.errno == errno.EIO
        F.maybe_fire("p")                      # arrival 4: clean again
    assert [f.count for f in plan.fired] == [2, 3]


def test_plan_no_active_plan_is_noop():
    F.maybe_fire("anything")                   # must never raise


def test_plan_env_roundtrip():
    plan = F.FaultPlan([F.FaultSpec("a", "crash", hit=3)], seed=7)
    back = F.FaultPlan.from_env(plan.to_env())
    assert back.specs == plan.specs and back.seed == 7


def test_plan_rejects_unknown_kind():
    with pytest.raises(ValueError, match="unknown fault kind"):
        F.FaultSpec("p", "meteor")


def test_bitflip_corrupts_past_npy_header(tmp_path):
    path = str(tmp_path / "x.npy")
    arr = np.arange(256, dtype=np.float32)
    np.save(path, arr)
    plan = F.FaultPlan([F.FaultSpec("w", "bitflip", nbytes=4)], seed=0)
    with F.install(plan):
        F.maybe_fire("w", path=path)
    loaded = np.load(path)                     # header intact: parses
    assert loaded.shape == arr.shape
    assert not np.array_equal(loaded, arr)     # payload corrupted


# --------------------------------------------------------------- retry

def test_retry_absorbs_transient_window_within_budget():
    plan = F.FaultPlan([F.FaultSpec("io", "enospc", hit=1, times=2)])
    pol = F.RetryPolicy(max_retries=2, base_delay_s=0)
    calls = []
    with F.install(plan):
        pol.call(lambda: calls.append(F.maybe_fire("io")))
    assert len(calls) == 1                     # succeeded on attempt 3


def test_retry_exhausted_reraises():
    plan = F.FaultPlan([F.FaultSpec("io", "eio", hit=1, times=5)])
    pol = F.RetryPolicy(max_retries=2, base_delay_s=0)
    with F.install(plan), pytest.raises(OSError):
        pol.call(lambda: F.maybe_fire("io"))


def test_retry_never_retries_corruption():
    pol = F.RetryPolicy(max_retries=5, base_delay_s=0)
    calls = []

    def bad():
        calls.append(1)
        raise CorruptCheckpointError("bad crc")

    with pytest.raises(CorruptCheckpointError):
        pol.call(bad)
    assert len(calls) == 1


# ----------------------------------------------------- committed steps

def test_committed_steps_history_sorted_and_verified(tmp_path):
    base = str(tmp_path)
    for step in (30, 10, 20):
        _save(base, step)
    # wreckage that must all be invisible: torn tmp dir, empty dir,
    # manifest that doesn't parse, manifest whose step lies
    os.makedirs(tmp_path / "step_00000040.tmp-123")
    os.makedirs(tmp_path / "step_00000050")
    (tmp_path / "step_00000060").mkdir()
    (tmp_path / "step_00000060" / "manifest.json").write_text("{not json")
    (tmp_path / "step_00000070").mkdir()
    (tmp_path / "step_00000070" / "manifest.json").write_text(
        json.dumps({"step": 999}))
    assert committed_steps(base) == [10, 20, 30]
    assert ckpt_lib.latest_step(base) == 30


# ----------------------------------------- save_sharded crash matrix

CRASH_POINTS = ["sharded.write", "sharded.written", "sharded.manifest",
                "sharded.committed"]


@pytest.mark.parametrize("point", CRASH_POINTS)
def test_save_crash_matrix_new_step(tmp_path, point):
    """SIGKILL at any injection point of a new-step save: latest_step is
    either the old committed step or the new one, never a torn dir, and
    whatever it names restores."""
    base = str(tmp_path)
    code = """
import numpy as np
from repro import ckpt as C
from repro.faults import FaultPlan, FaultSpec, install
base = %r
tree = {"w": np.arange(64, dtype=np.float32), "b": np.float32(2.0),
        "k": np.arange(6, dtype=np.int32).reshape(2, 3)}
C.save_sharded(C.step_dir(base, 10), 10, tree)
with install(FaultPlan([FaultSpec(%r, "crash")])):
    C.save_sharded(C.step_dir(base, 20), 20, tree)
print("SURVIVED")
""" % (base, point)
    res = harness.run_child(code)
    harness.expect_sigkill(res)
    last = ckpt_lib.latest_step(base)
    assert last in (10, 20), f"torn step visible after crash at {point}"
    step, tree = ckpt_lib.restore_auto(
        ckpt_lib.step_dir(base, last), _tree())
    assert step == last
    np.testing.assert_array_equal(tree["w"], _tree()["w"])


@pytest.mark.parametrize("point", ["sharded.pre_rename_aside",
                                   "sharded.between_renames"])
def test_save_crash_matrix_same_step_resave(tmp_path, point):
    """The same-step re-save crash windows: a kill before the rename-
    aside keeps step 10 committed; a kill between the renames hides it
    (falls back to step 5) but never exposes a torn dir."""
    base = str(tmp_path)
    code = """
import numpy as np
from repro import ckpt as C
from repro.faults import FaultPlan, FaultSpec, install
base = %r
tree = {"w": np.arange(64, dtype=np.float32), "b": np.float32(2.0),
        "k": np.arange(6, dtype=np.int32).reshape(2, 3)}
C.save_sharded(C.step_dir(base, 5), 5, tree)
C.save_sharded(C.step_dir(base, 10), 10, tree)
with install(FaultPlan([FaultSpec(%r, "crash")])):
    C.save_sharded(C.step_dir(base, 10), 10, tree)
print("SURVIVED")
""" % (base, point)
    res = harness.run_child(code)
    harness.expect_sigkill(res)
    last = ckpt_lib.latest_step(base)
    if point == "sharded.pre_rename_aside":
        assert last == 10
    else:
        # step 10 was moved aside pre-commit: fall back to step 5; the
        # .old-* bytes survive until the next save's debris sweep
        assert last in (5, 10)
    step, _tree_out = ckpt_lib.restore_auto(
        ckpt_lib.step_dir(base, last), _tree())
    assert step == last


# ----------------------------------------------------------- debris GC

def test_gc_debris_collects_dead_pid_leftovers(tmp_path):
    base = str(tmp_path)
    _save(base, 10)
    dead = _dead_pid()
    planted_old = tmp_path / f"step_00000010.old-{dead}"
    planted_tmp = tmp_path / f"step_00000020.tmp-{dead}"
    live = tmp_path / f"step_00000030.tmp-{os.getpid()}"
    quarantined = tmp_path / f"step_00000005.quarantined-{dead}"
    for d in (planted_old, planted_tmp, live, quarantined):
        d.mkdir()
        (d / "junk.npy").write_bytes(b"x")
    _save(base, 40)                            # sweep rides the commit
    assert not planted_old.exists(), ".old-* of a dead pid must be GCed"
    assert not planted_tmp.exists(), ".tmp-* of a dead pid must be GCed"
    assert live.exists(), "a live writer's tmp dir must be left alone"
    assert quarantined.exists(), "quarantined dirs are evidence, not GCed"
    assert committed_steps(base) == [10, 40]


def test_gc_debris_direct_call(tmp_path):
    dead = _dead_pid()
    d = tmp_path / f"step_00000001.old-{dead}"
    d.mkdir()
    removed = ckpt_lib.gc_debris(str(tmp_path))
    assert removed == [str(d)] and not d.exists()


# ------------------------------------------- quarantine + fallback

def _corrupt_one_shard(step_path: str):
    """Flip payload bytes of one .npy so its CRC fails but np.load works."""
    files = sorted(f for f in os.listdir(step_path) if f.endswith(".npy"))
    path = os.path.join(step_path, files[0])
    with open(path, "r+b") as f:
        f.seek(-4, os.SEEK_END)
        tail = f.read(4)
        f.seek(-4, os.SEEK_END)
        f.write(bytes(b ^ 0xFF for b in tail))


def test_corrupt_newest_falls_back_with_report(tmp_path):
    base = str(tmp_path)
    _save(base, 10)
    _save(base, 20)
    _corrupt_one_shard(ckpt_lib.step_dir(base, 20))
    with pytest.raises(CorruptCheckpointError, match="checksum"):
        ckpt_lib.restore_sharded(ckpt_lib.step_dir(base, 20), _tree())
    step, tree, rep = restore_with_fallback(base, _tree())
    assert step == 10
    np.testing.assert_array_equal(tree["w"], _tree()["w"])
    assert rep.fell_back and rep.restored_step == 10
    assert [q.step for q in rep.quarantined] == [20]
    assert rep.attempted == [20, 10]
    # quarantined on disk: renamed out of the committed namespace
    assert not os.path.isdir(ckpt_lib.step_dir(base, 20))
    assert os.path.isdir(rep.quarantined[0].quarantined_to)
    assert ckpt_lib.latest_step(base) == 10


def test_truncated_shard_falls_back_too(tmp_path):
    base = str(tmp_path)
    _save(base, 10)
    _save(base, 20)
    sdir = ckpt_lib.step_dir(base, 20)
    files = sorted(f for f in os.listdir(sdir) if f.endswith(".npy"))
    path = os.path.join(sdir, files[0])
    os.truncate(path, os.path.getsize(path) // 2)
    step, _tree_out, rep = restore_with_fallback(base, _tree())
    assert step == 10 and [q.step for q in rep.quarantined] == [20]


def test_unrecoverable_corruption_fails_loudly(tmp_path):
    base = str(tmp_path)
    for s in (10, 20):
        _save(base, s)
        _corrupt_one_shard(ckpt_lib.step_dir(base, s))
    with pytest.raises(CorruptCheckpointError, match="every committed"):
        restore_with_fallback(base, _tree())


def test_no_commit_fails_loudly(tmp_path):
    with pytest.raises(CorruptCheckpointError, match="no committed"):
        restore_with_fallback(str(tmp_path), _tree())


def test_fallback_respects_quarantine_off(tmp_path):
    base = str(tmp_path)
    _save(base, 10)
    _save(base, 20)
    _corrupt_one_shard(ckpt_lib.step_dir(base, 20))
    step, _t, rep = restore_with_fallback(base, _tree(),
                                          quarantine_on_disk=False)
    assert step == 10
    assert rep.quarantined[0].quarantined_to is None
    assert os.path.isdir(ckpt_lib.step_dir(base, 20))  # left in place


def test_walk_committed_max_fallbacks(tmp_path):
    base = str(tmp_path)
    for s in (10, 20, 30):
        _save(base, s)
        _corrupt_one_shard(ckpt_lib.step_dir(base, s))

    def attempt(step, path):
        return ckpt_lib.restore_sharded(path, _tree())

    with pytest.raises(CorruptCheckpointError):
        walk_committed(base, attempt, max_fallbacks=1,
                       quarantine_on_disk=False)


# ------------------------------------ injected faults on the I/O path

def test_transient_read_fault_retried_then_restores(tmp_path):
    base = str(tmp_path)
    _save(base, 10)
    plan = F.FaultPlan([F.FaultSpec("sharded.read", "eio", hit=1)])
    with F.install(plan):
        with pytest.raises(OSError):
            ckpt_lib.restore_sharded(ckpt_lib.step_dir(base, 10), _tree())
    plan = F.FaultPlan([F.FaultSpec("sharded.read", "eio", hit=1)])
    with F.install(plan):
        step, tree = ckpt_lib.restore_sharded(
            ckpt_lib.step_dir(base, 10), _tree(),
            retry=F.RetryPolicy(max_retries=1, base_delay_s=0))
    assert step == 10 and plan.fired


def test_transient_write_fault_retried_whole_protocol(tmp_path):
    base = str(tmp_path)
    plan = F.FaultPlan([F.FaultSpec("sharded.write", "enospc", hit=2)])
    with F.install(plan):
        _save(base, 10, retry=F.RetryPolicy(max_retries=1,
                                            base_delay_s=0))
    assert committed_steps(base) == [10]
    step, tree = ckpt_lib.restore_auto(ckpt_lib.step_dir(base, 10),
                                       _tree())
    np.testing.assert_array_equal(tree["k"], _tree()["k"])


def test_write_fault_without_retry_surfaces_and_commits_nothing(tmp_path):
    base = str(tmp_path)
    plan = F.FaultPlan([F.FaultSpec("sharded.write", "enospc", hit=1)])
    with F.install(plan), pytest.raises(OSError):
        _save(base, 10)
    assert committed_steps(base) == []


def test_async_writer_surfaces_injected_fault_at_join(tmp_path):
    sdir = ckpt_lib.step_dir(str(tmp_path), 10)
    plan = F.FaultPlan([F.FaultSpec("sharded.manifest", "enospc")])
    with F.install(plan):
        t = ckpt_lib.save_sharded(sdir, 10, _tree(), blocking=False)
        with pytest.raises(OSError):
            t.join()
    assert committed_steps(str(tmp_path)) == []


def test_bitflip_post_crc_caught_only_by_reader(tmp_path):
    """bitflip at sharded.written corrupts AFTER the CRC was computed:
    the save commits happily; the reader's checksum is the only
    defense — exactly the case quarantine+fallback exists for."""
    base = str(tmp_path)
    _save(base, 10)
    plan = F.FaultPlan([F.FaultSpec("sharded.written", "bitflip",
                                    hit=1, nbytes=8)], seed=3)
    with F.install(plan):
        _save(base, 20)
    assert committed_steps(base) == [10, 20]   # save saw nothing wrong
    step, _t, rep = restore_with_fallback(base, _tree())
    assert step == 10 and [q.step for q in rep.quarantined] == [20]


# ------------------------------------------------------ legacy format

def test_legacy_manifest_fault_leaves_no_commit(tmp_path):
    from repro import checkpoint as legacy
    sdir = ckpt_lib.step_dir(str(tmp_path), 10)
    plan = F.FaultPlan([F.FaultSpec("legacy.manifest", "enospc")])
    with F.install(plan), pytest.raises(OSError):
        legacy.save(sdir, 10, _tree())
    assert committed_steps(str(tmp_path)) == []


# ------------------------------------------------- launcher flag guards

def _main_with(argv):
    from repro.launch.train import main
    old = sys.argv
    sys.argv = ["train"] + argv
    try:
        main()
    finally:
        sys.argv = old


def test_launcher_rejects_fallback_without_resume():
    with pytest.raises(SystemExit, match="fallback-on-corrupt"):
        _main_with(["--no-resume", "--fallback-on-corrupt"])


def test_launcher_rejects_retries_without_resume():
    with pytest.raises(SystemExit, match="max-restore-retries"):
        _main_with(["--no-resume", "--max-restore-retries", "3"])


def test_launcher_rejects_negative_retries():
    with pytest.raises(SystemExit, match=">= 0"):
        _main_with(["--max-restore-retries", "-1"])


# ------------------------------------------- namespaced (cluster) plans

def test_plans_to_env_arms_only_matching_job(monkeypatch):
    from repro.faults import plan as plan_mod

    env = F.plans_to_env({
        "j1": F.FaultPlan([F.FaultSpec("p", "eio")], seed=7),
        "j2": F.FaultPlan([F.FaultSpec("q", "enospc")], seed=9),
    })
    monkeypatch.setenv(F.ENV_VAR, env)
    prev = plan_mod._ACTIVE
    try:
        got = F.install_from_env("j1")
        assert got is not None and got.seed == 7
        assert [s.point for s in got.specs] == ["p"]
        assert F.active_plan() is got
        with pytest.raises(OSError):
            F.maybe_fire("p")
    finally:
        plan_mod._ACTIVE = prev


def test_plans_to_env_untargeted_job_arms_nothing(monkeypatch):
    from repro.faults import plan as plan_mod

    env = F.plans_to_env({"j1": F.FaultPlan([F.FaultSpec("p", "eio")])})
    monkeypatch.setenv(F.ENV_VAR, env)
    prev = plan_mod._ACTIVE
    try:
        plan_mod._ACTIVE = None
        assert F.install_from_env("other") is None
        assert F.active_plan() is None
        F.maybe_fire("p")                     # neighbor: must not raise
        # no job id at all (no $REPRO_JOB_ID either): also nothing
        assert F.install_from_env() is None
    finally:
        plan_mod._ACTIVE = prev


def test_install_from_env_job_id_defaults_to_env_var(monkeypatch):
    from repro.faults import plan as plan_mod

    env = F.plans_to_env({"me": F.FaultPlan([F.FaultSpec("p", "eio")])})
    monkeypatch.setenv(F.ENV_VAR, env)
    monkeypatch.setenv(F.JOB_ENV_VAR, "me")
    prev = plan_mod._ACTIVE
    try:
        got = F.install_from_env()
        assert got is not None and [s.point for s in got.specs] == ["p"]
    finally:
        plan_mod._ACTIVE = prev


def test_install_from_env_legacy_format_arms_unconditionally(monkeypatch):
    from repro.faults import plan as plan_mod

    plan = F.FaultPlan([F.FaultSpec("p", "eio")], seed=3)
    monkeypatch.setenv(F.ENV_VAR, plan.to_env())
    prev = plan_mod._ACTIVE
    try:
        got = F.install_from_env("any-job-id")
        assert got is not None and got.seed == 3
    finally:
        plan_mod._ACTIVE = prev
