"""Property tests: shard -> manifest -> reshard round-trips exactly.

The reshard-on-restore guarantee is pure offset arithmetic: a flat
bucket saved as F_old contiguous per-rank shards, re-read as F_new
contiguous target shards under a (possibly different) padded size, must
recover every *leaf* of the original pytree bit-for-bit — padding is
zeros on both sides, so only the live prefix matters.  These properties
drive the real manifest dataclasses and the real ``ShardedCheckpoint``
range reader over randomized bucket layouts and mesh factorizations,
with no jax mesh involved (the arithmetic is host-side).

Uses real ``hypothesis`` when installed, else the deterministic shim in
``tests/_hypothesis_stub.py``.
"""
import os
import tempfile
import zlib

import numpy as np

try:
    from hypothesis import given, settings, strategies as st
except ImportError:      # no-network env: deterministic example-based shim
    from tests._hypothesis_stub import given, settings, st

from repro import ckpt
from repro.collectives import bucketing as BK


def _round_up(n, a):
    return -(-n // a) * a


def _random_leaves(seed: int, n_leaves: int):
    rng = np.random.default_rng(seed)
    return {f"l{i}": rng.standard_normal(
        int(rng.integers(1, 40))).astype(np.float32)
        for i in range(n_leaves)}


def _flatten_np(layout, leaves_dict, bucket_sizes):
    """Host-side flatten: the numpy mirror of ``flatten_to_buckets``."""
    leaves = [leaves_dict[k] for k in sorted(leaves_dict)]
    buckets = [np.zeros(c, np.float32) for c in bucket_sizes]
    for leaf, slot in zip(leaves, layout.slots):
        buckets[slot.bucket][slot.offset:slot.offset + slot.size] = \
            leaf.reshape(-1)
    return buckets


def _write_sharded(d, name, arr, n_shards):
    """Write ``arr`` as ``n_shards`` contiguous shard files + entries."""
    n = arr.shape[0]
    assert n % n_shards == 0
    sz = n // n_shards
    shards = []
    for r in range(n_shards):
        a, b = r * sz, (r + 1) * sz
        fname = f"{name}.s{r}.npy"
        np.save(os.path.join(d, fname), arr[a:b])
        shards.append(ckpt.ShardFile(
            file=fname, index=((a, b),),
            crc32=zlib.crc32(arr[a:b].tobytes()) & 0xffffffff))
    return ckpt.LeafEntry(kind="sharded", shape=(n,), dtype="float32",
                          shards=tuple(shards))


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10_000),
       n_leaves=st.integers(min_value=1, max_value=6),
       bucket_bytes=st.sampled_from([64, 128, 256, 1024]),
       align_old=st.sampled_from([1, 2, 3, 4, 6, 8, 64]),
       align_new=st.sampled_from([1, 2, 3, 4, 6, 8, 64]),
       f_old=st.sampled_from([1, 2, 4, 8]),
       f_new=st.sampled_from([1, 2, 4, 8]))
def test_shard_manifest_reshard_recovers_leaves(seed, n_leaves,
                                                bucket_bytes, align_old,
                                                align_new, f_old, f_new):
    """Save with (align_old, F_old), restore with (align_new, F_new):
    every leaf recovers exactly; slot placement is align-invariant."""
    leaves = _random_leaves(seed, n_leaves)
    # shard counts must divide the padded sizes: fold them into align
    lay_old = BK.plan_buckets(leaves, bucket_bytes=bucket_bytes,
                              align=align_old * f_old)
    lay_new = BK.plan_buckets(leaves, bucket_bytes=bucket_bytes,
                              align=align_new * f_new)
    # bucket boundaries (slot placement) are a pure function of the leaf
    # sizes + capacity, never of the alignment — the invariant reshard
    # leans on
    assert [ (s.bucket, s.offset, s.size) for s in lay_old.slots ] == \
           [ (s.bucket, s.offset, s.size) for s in lay_new.slots ]

    old_buckets = _flatten_np(lay_old, leaves, lay_old.bucket_sizes)
    with tempfile.TemporaryDirectory() as d:
        entries = {}
        for b, arr in enumerate(old_buckets):
            entries[f"bucket[{b}]"] = _write_sharded(
                d, f"bucket_{b}", arr, f_old)
        man = ckpt.Manifest(step=7, leaves=entries)
        with open(os.path.join(d, ckpt.MANIFEST), "w") as f:
            f.write(man.to_json())

        reader = ckpt.ShardedCheckpoint(d)
        assert reader.step == 7
        # assemble each *target* bucket shard-by-shard (F_new reads of
        # C_new/F_new elements each — the restore access pattern)
        new_buckets = []
        for b, c_new in enumerate(lay_new.bucket_sizes):
            sz = c_new // f_new
            parts = [reader.read_box(f"bucket[{b}]",
                                     ((r * sz, (r + 1) * sz),))
                     for r in range(f_new)]
            for p in parts:
                assert p.shape == (sz,)          # never a full bucket
            new_buckets.append(np.concatenate(parts))
        for leaf_key, slot in zip(sorted(leaves), lay_new.slots):
            got = new_buckets[slot.bucket][
                slot.offset:slot.offset + slot.size]
            np.testing.assert_array_equal(got, leaves[leaf_key],
                                          err_msg=leaf_key)
        # padding past the live prefix restores as zeros
        live = ckpt.bucket_live_sizes(lay_new)
        for b, c_new in enumerate(lay_new.bucket_sizes):
            assert not new_buckets[b][live[b]:].any()


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10_000),
       n=st.integers(min_value=4, max_value=64),
       f=st.sampled_from([1, 2, 4]))
def test_manifest_json_roundtrip_and_crc(seed, n, f):
    """Manifest serialization round-trips; checksums catch torn bytes."""
    rng = np.random.default_rng(seed)
    arr = rng.standard_normal(_round_up(n, f)).astype(np.float32)
    with tempfile.TemporaryDirectory() as d:
        entry = _write_sharded(d, "x", arr, f)
        man = ckpt.Manifest(step=3, leaves={"x": entry},
                            mesh={"axis_names": ["pod", "data"],
                                  "shape": [2, 2]})
        text = man.to_json()
        man2 = ckpt.Manifest.from_json(text)
        assert man2.step == 3 and man2.mesh == man.mesh
        assert man2.leaves["x"] == entry
        with open(os.path.join(d, ckpt.MANIFEST), "w") as fh:
            fh.write(text)
        reader = ckpt.ShardedCheckpoint(d)
        np.testing.assert_array_equal(reader.read_leaf("x"), arr)
        # flip a byte in one shard: the ranged read must detect it
        fname = os.path.join(d, entry.shards[0].file)
        bad = np.load(fname)
        bad[0] += 1.0
        np.save(fname, bad)
        try:
            ckpt.ShardedCheckpoint(d).read_leaf("x")
        except ckpt.CorruptCheckpointError:
            pass
        else:
            raise AssertionError("corruption not detected")
