"""Golden bake-off table: pinned metrics per (policy, trace, seed) cell.

Complements ``test_trace_replay_golden.py`` (which proves the
pre-existing mode/policy rows stayed bit-identical through the
fleet-scale simulator hardening): this table pins the full bake-off
matrix INCLUDING the new fragmentation-aware cells, at the same sizes
the CI sched-bakeoff job replays, so any change to placement scoring,
tie-breaking, event ordering or the frag-integral bookkeeping shows up
as an exact float diff here rather than as a silent re-keying of
BENCH_sched.json.

Values are ``repr``-exact (full float precision): equality is ==, not
approx — determinism is the property under test.
"""
import pytest

from repro.core.simulator import simulate
from repro.core.traces import (TraceCategory, generate_fleet_trace,
                               generate_trace)

# cell -> (mode, simulate kwargs); mirrors benchmarks/sched_bench.py
CELLS = {
    "fm/fifo": ("FM", {"policy": "fifo"}),
    "fm/backfill": ("FM", {"policy": "backfill"}),
    "fm-frag/fifo": ("FM", {"policy": "fifo",
                            "placement": "frag_aware"}),
    "fm-frag/backfill": ("FM", {"policy": "backfill",
                                "placement": "frag_aware"}),
    "dm/fifo": ("DM", {"policy": "fifo"}),
    "sm/fifo": ("SM", {"policy": "fifo"}),
}

N_HOSTS = {"philly": 4, "helios_earth": 4, "fleet": 8}

# (family, cell, seed) -> (makespan, avg_jct, avg_wait,
#                          avg_frag_slices, utilization)
GOLDEN = {
    ("philly", "fm/fifo", 7): (6397.242468961668, 1822.8249327580734, 165.65292532576876, 0.9216835582834524, 0.3734419073826111),
    ("philly", "fm/backfill", 7): (6397.242468961668, 1848.5825394412589, 120.3743497997771, 0.7416110444108294, 0.37520208888625),
    ("philly", "fm-frag/fifo", 7): (6397.242468961668, 1891.2589782091488, 151.0151877172112, 0.6412191831475204, 0.3876471323870199),
    ("philly", "fm-frag/backfill", 7): (6397.242468961668, 1874.6214341539205, 125.05375020474041, 0.48572372019032356, 0.3862074387885268),
    ("philly", "dm/fifo", 7): (7557.35371404094, 1934.7769052604092, 896.9312012003227, 1.9238716517546923, 0.32773294170480505),
    ("philly", "sm/fifo", 7): (7307.35371404094, 1866.3898084862153, 165.83974272096202, 1.9656974681189823, 0.32130455013424103),
    ("helios_earth", "fm/fifo", 7): (6397.242468961668, 2097.8084061839204, 196.00907310359435, 0.898253822582491, 0.43214203148472746),
    ("helios_earth", "fm/backfill", 7): (6397.242468961668, 2112.344874184839, 149.62450739474377, 0.9616826013700223, 0.43339991304802494),
    ("helios_earth", "fm-frag/fifo", 7): (6397.242468961668, 2140.8903395086213, 173.903667715251, 0.5926652918148275, 0.44415346674375894),
    ("helios_earth", "fm-frag/backfill", 7): (6397.242468961668, 2140.8903395086213, 141.1943728595582, 0.43033166192778366, 0.44415346674375883),
    ("helios_earth", "dm/fifo", 7): (7687.35371404094, 2197.31850133484, 1030.983120347518, 1.8474667381004761, 0.3705450063719933),
    ("helios_earth", "sm/fifo", 7): (7307.35371404094, 2124.4152755283885, 182.05479620159483, 2.152264876521413, 0.37023377511920463),
    ("fleet", "fm/fifo", 11): (137207.72491053774, 2366.095611250014, 53373.91662586262, 4.05033726969476, 0.8682327119418682),
    ("fleet", "fm/backfill", 11): (127075.83742739692, 2363.619237888327, 47789.575492012766, 1.8877365348642068, 0.9346894637248205),
    ("fleet", "fm-frag/fifo", 11): (137266.94918283616, 2417.6444114112046, 53839.755622861456, 2.4489110184019953, 0.8918998231279217),
    ("fleet", "fm-frag/backfill", 11): (129465.22558664104, 2417.3602999154864, 49812.502310623604, 1.29976354779211, 0.9461805448761064),
}


def _trace(family, seed):
    if family == "fleet":
        return generate_fleet_trace(2000, seed=seed,
                                    mean_interarrival=10.0)
    return generate_trace(TraceCategory(family, "balanced", "mixed"),
                          seed=seed, double=False, max_size=4)


def _metrics(family, cell, seed):
    mode, kw = CELLS[cell]
    res = simulate(_trace(family, seed), mode,
                   n_hosts=N_HOSTS[family], **kw)
    return (res.makespan, res.avg_jct, res.avg_wait,
            res.avg_frag_slices, res.utilization)


@pytest.mark.parametrize("family,cell,seed", sorted(GOLDEN))
def test_bakeoff_cell_golden(family, cell, seed):
    got = _metrics(family, cell, seed)
    want = GOLDEN[(family, cell, seed)]
    assert got == want, (
        f"({family}, {cell}, seed={seed}) drifted:\n"
        f"  got  {got!r}\n  want {want!r}\n"
        f"Placement scoring, tie-breaking and event ordering are pinned "
        f"— if the change is intentional, regenerate this table.")


def test_frag_aware_beats_default_on_fragmentation():
    """The bake-off's headline acceptance, pinned at golden scale: the
    frag-aware FIFO cell strands less time-averaged fragmentation than
    default FM FIFO on every family in the table."""
    fams = {f for f, _, _ in GOLDEN}
    for fam in fams:
        seed = 11 if fam == "fleet" else 7
        frag = GOLDEN[(fam, "fm-frag/fifo", seed)][3]
        base = GOLDEN[(fam, "fm/fifo", seed)][3]
        assert frag < base, (fam, frag, base)


def test_double_run_bit_identical():
    """Same (policy, trace, seed) twice -> byte-for-byte equal metrics
    and per-job JCT maps (simulate must not mutate shared state)."""
    jobs = _trace("philly", 7)
    a = simulate(jobs, "FM", n_hosts=4, policy="backfill",
                 placement="frag_aware")
    b = simulate(jobs, "FM", n_hosts=4, policy="backfill",
                 placement="frag_aware")
    assert a.jct_by_job == b.jct_by_job
    assert a.wait_by_job == b.wait_by_job
    assert (a.makespan, a.avg_frag_slices, a.n_events) == \
        (b.makespan, b.avg_frag_slices, b.n_events)
