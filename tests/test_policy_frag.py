"""Fragmentation-aware placement scoring: properties + determinism.

The three properties ISSUE's bake-off hangs on:

1. the frag score is ZERO for an exact-fit placement;
2. it is MONOTONE under pointwise dominance of per-size stranded
   counts (more idle leaves stranded for every demanded size -> score
   at least as large);
3. :func:`frag_aware_choose_host` is the exact argmin of the
   post-placement score over feasible hosts (checked against a brute
   force that re-scores every host).

Plus the satellite bugfix pins: ``choose_host``, ``frag_aware_choose_
host`` and ``defrag_victims`` tie-breaking is explicitly deterministic.

Uses real ``hypothesis`` when installed, else the deterministic shim in
``tests/_hypothesis_stub.py`` (same strategy API).
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                                    # pragma: no cover
    from tests._hypothesis_stub import given, settings, st

from repro.core.job import TIER_HIGH, TIER_NORMAL, Job
from repro.core.leaves import Cluster
from repro.core.modes import FlexMIG
from repro.core.policy import (DEFAULT_FRAG_DEMAND, choose_host,
                               cluster_frag, cluster_placement,
                               defrag_victims, frag_aware_choose_host,
                               frag_aware_select_instances,
                               frag_score_host, stranded_frag)
from repro.cluster.pool import DevicePool, PoolError

LEAVES_PER_HOST = 14          # FLEXMIG_PARTITION x 2 GPUs


def _cluster(n_hosts=3):
    c = Cluster(n_hosts=n_hosts, gpus_per_host=2)
    FlexMIG().setup(c)
    return c


def _occupy(cluster, host, n, jid="filler"):
    """Mark ``n`` idle leaves busy on ``host`` (arbitrary but
    deterministic order)."""
    taken = 0
    for gpu in cluster.host_gpus(host):
        for inst in gpu.instances:
            if taken == n:
                return
            if not inst.busy:
                cluster.mark_busy(inst, f"{jid}-{host}-{taken}")
                taken += 1
    assert taken == n, f"host {host} lacked {n} idle leaves"


# ---------------------------------------------------------------- score

def test_exact_fit_scores_zero():
    assert stranded_frag(0) == 0.0
    c = _cluster(1)
    # size == all idle leaves -> exact fit -> zero stranded frag
    assert frag_score_host(c, 0, LEAVES_PER_HOST) == 0.0


@settings(max_examples=50, deadline=None)
@given(idle=st.integers(min_value=0, max_value=LEAVES_PER_HOST))
def test_score_zero_iff_exact_fit_or_unstrandable(idle):
    """F(idle) == 0 exactly when idle == 0 or no demanded size exceeds
    idle (nothing is stranded for any demand)."""
    score = stranded_frag(idle)
    largest = max(s for s, _ in DEFAULT_FRAG_DEMAND)
    if idle == 0 or idle >= largest:
        assert score == 0.0
    else:
        assert score > 0.0


@settings(max_examples=50, deadline=None)
@given(idle_a=st.integers(min_value=0, max_value=20),
       idle_b=st.integers(min_value=0, max_value=20))
def test_monotone_under_pointwise_dominance(idle_a, idle_b):
    """If A strands at least as many leaves as B for EVERY demanded
    size, F(A) >= F(B).  With the single-host score, A's per-size
    stranded count is ``idle_a * [idle_a < s]``; dominance holds
    whenever that is >= B's for all s — check the implication."""
    stranded = lambda idle, s: idle if idle < s else 0  # noqa: E731
    dominates = all(stranded(idle_a, s) >= stranded(idle_b, s)
                    for s, _ in DEFAULT_FRAG_DEMAND)
    if dominates:
        assert stranded_frag(idle_a) >= stranded_frag(idle_b)


def test_score_rejects_negative_idle():
    with pytest.raises(ValueError):
        stranded_frag(-1)


@settings(max_examples=30, deadline=None)
@given(busy0=st.integers(min_value=0, max_value=LEAVES_PER_HOST),
       busy1=st.integers(min_value=0, max_value=LEAVES_PER_HOST),
       busy2=st.integers(min_value=0, max_value=LEAVES_PER_HOST),
       size=st.sampled_from([1, 2, 4, 6, 8]))
def test_frag_aware_choose_host_is_argmin(busy0, busy1, busy2, size):
    """frag_aware_choose_host == brute-force argmin of post-placement F
    over feasible hosts (ties: fewest leftover idle, then lowest id)."""
    c = _cluster(3)
    for h, busy in enumerate((busy0, busy1, busy2)):
        _occupy(c, h, busy)
    got = frag_aware_choose_host(c, size)
    feasible = [(frag_score_host(c, h, size),
                 c.idle_leaf_count(h) - size, h)
                for h in range(3) if c.idle_leaf_count(h) >= size]
    if not feasible:
        assert got is None
    else:
        assert got == min(feasible)[2]


# ----------------------------------------------------- tie determinism

def test_choose_host_tie_breaks_to_lowest_id():
    c = _cluster(3)           # all hosts equally idle
    assert choose_host(c, 2) == 0
    _occupy(c, 0, 4)          # host 1 and 2 now tie for most idle
    assert choose_host(c, 2) == 1


def test_frag_aware_choose_host_tie_breaks_to_lowest_id():
    c = _cluster(3)
    assert frag_aware_choose_host(c, 2) == 0
    # hosts 1,2 each have exactly 2 idle leaves: both are exact fits
    # (F=0, leftover 0) and tie; host 0 is pristine (F(12)=0 too — idle
    # above the largest demanded size strands nothing) but loses on the
    # leftover-idle tiebreak.  Lowest id among the tied exact fits wins.
    _occupy(c, 1, LEAVES_PER_HOST - 2)
    _occupy(c, 2, LEAVES_PER_HOST - 2)
    assert frag_aware_choose_host(c, 2) == 1


def test_frag_aware_prefers_exact_fit_host():
    c = _cluster(3)
    _occupy(c, 1, LEAVES_PER_HOST - 2)    # host 1: exactly 2 idle
    assert frag_aware_choose_host(c, 2) == 1
    # and placing there zeroes its contribution to cluster frag
    before = cluster_frag(c)
    _occupy(c, 1, 2, jid="fit")
    assert cluster_frag(c) < before


def test_defrag_victims_equal_keys_keep_caller_order():
    js = [Job(f"j{i}", "resnet50", "train", 2, 256, 1000.0)
          for i in (3, 1, 2)]               # non-lexicographic ids
    req = Job("req", "resnet50", "train", 4, 256, 1000.0)
    assert [j.job_id for j in defrag_victims(js, req)] == \
        ["j3", "j1", "j2"]                  # stable: insertion order
    # reversed input -> reversed (still caller) order
    assert [j.job_id for j in defrag_victims(js[::-1], req)] == \
        ["j2", "j1", "j3"]


def test_defrag_victims_never_moves_higher_priority():
    hi = Job("hi", "resnet50", "train", 2, 256, 1000.0,
             priority_tier=TIER_HIGH)
    lo = Job("lo", "resnet50", "train", 2, 256, 1000.0)
    req = Job("req", "resnet50", "train", 4, 256, 1000.0,
              priority_tier=TIER_NORMAL)
    assert [j.job_id for j in defrag_victims([hi, lo], req)] == ["lo"]


# --------------------------------------------- leaf-granularity select

def test_frag_aware_select_consumes_fragmented_gpu_first():
    c = _cluster(1)
    gpus = list(c.host_gpus(0))
    # fragment gpu 1: one leaf busy
    busy_inst = gpus[1].instances[0]
    c.mark_busy(busy_inst, "frag")
    chosen = frag_aware_select_instances(c, 0, 2)
    assert chosen is not None
    assert {i.gpu_id for i in chosen} == {gpus[1].gpu_id}, \
        "should finish the fragmented GPU before breaking a pristine one"


def test_frag_aware_select_size_aware_profile_preference():
    c = _cluster(1)
    chosen = frag_aware_select_instances(c, 0, 1)
    assert chosen is not None and len(chosen) == 1
    assert chosen[0].profile == "1g.10gb"   # size-1 prefers big memory


def test_frag_aware_select_insufficient_returns_none():
    c = _cluster(1)
    _occupy(c, 0, LEAVES_PER_HOST - 1)
    assert frag_aware_select_instances(c, 0, 2) is None


def test_fm_frag_aware_placement_mode():
    c = _cluster(2)
    fm = FlexMIG(placement="frag_aware")
    pl = fm.try_place(Job("a", "resnet50", "train", 2, 256, 1000.0), c)
    assert pl is not None
    with pytest.raises(ValueError):
        FlexMIG(placement="nope")


# --------------------------------------- host-granularity (pool) plane

def test_cluster_placement_frag_aware_flag():
    # default unchanged
    assert cluster_placement(TIER_NORMAL, 4, 8) == ("round_robin", None)
    assert cluster_placement(TIER_HIGH, 4, 8) == ("packed", 1)
    # frag-aware variants keep the SLA span pin
    assert cluster_placement(TIER_NORMAL, 4, 8, frag_aware=True) == \
        ("frag_aware", None)
    assert cluster_placement(TIER_HIGH, 4, 8, frag_aware=True) == \
        ("frag_aware", 1)


def test_pool_frag_aware_prefers_exact_fit():
    p = DevicePool(3, 8)
    p.allocate("a", range(0, 6), (1, 6))    # host 0: 2 free
    p.allocate("b", range(8, 12), (1, 4))   # host 1: 4 free
    devices, shape = p.plan(2, strategy="frag_aware")
    assert devices == (6, 7) and shape == (1, 2)    # exact fit host 0
    devices, shape = p.plan(4, strategy="frag_aware")
    assert devices == (12, 13, 14, 15) and shape == (1, 4)


def test_pool_frag_aware_narrowest_span_on_ties():
    p = DevicePool(2, 8)                    # empty pool: all hosts tie
    devices, shape = p.plan(8, strategy="frag_aware")
    assert shape == (1, 8), "span tie must consolidate (narrowest)"
    assert devices == tuple(range(8))


def test_pool_frag_aware_respects_require_span():
    p = DevicePool(2, 8)
    devices, shape = p.plan(4, strategy="frag_aware", require_span=2)
    assert shape == (2, 2)
    assert p.plan(3, strategy="frag_aware", require_span=2) is None


def test_pool_unknown_strategy_still_rejected():
    p = DevicePool(1, 4)
    with pytest.raises(PoolError):
        p.plan(1, strategy="best_fit")


@settings(max_examples=25, deadline=None)
@given(size=st.sampled_from([1, 2, 4, 8]),
       pre=st.integers(min_value=0, max_value=7))
def test_pool_frag_aware_matches_brute_force_single_span(size, pre):
    """For single-host-feasible sizes on a part-loaded pool, the chosen
    placement minimizes total post-placement stranded frag over all
    feasible (span, host set) plans."""
    p = DevicePool(3, 8)
    if pre:
        p.allocate("pre", range(pre), (1, pre))
    plan = p.plan(size, strategy="frag_aware")
    assert plan is not None
    free = p.free_by_host()

    def total_after(devs):
        used = set(devs)
        return sum(stranded_frag(len([d for d in f if d not in used]))
                   for f in free)

    # brute force over every feasible span/host-set combination
    import itertools
    best = None
    for span in (1, 2, 3):
        if size % span or size // span > 8:
            continue
        per = size // span
        for hosts in itertools.combinations(range(3), span):
            if any(len(free[h]) < per for h in hosts):
                continue
            devs = [d for h in hosts for d in free[h][:per]]
            best = min(best, total_after(devs)) \
                if best is not None else total_after(devs)
    assert best is not None
    assert total_after(plan[0]) == pytest.approx(best)
