"""Scheduler candidate selection (repro.core.scheduler).

Backfill window edge cases (satellite of the cluster-runtime PR): a
queue shorter than the depth, a head-of-line job that fits (backfill
must not reorder it), the empty queue, and depth=1 — plus the
multi-tenant extensions (per-tenant quotas, priority-tier ordering)
and their strict opt-in guarantees.
"""
from repro.core.job import TIER_HIGH, TIER_NORMAL, Job
from repro.core.scheduler import Scheduler, WaitQueue


def _job(jid, size=2, tenant="t0", tier=TIER_NORMAL):
    return Job(job_id=jid, model="m", kind="train", size=size, batch=8,
               base_duration=1.0, tenant=tenant, priority_tier=tier)


def _queue(*jobs):
    q = WaitQueue()
    for j in jobs:
        q.push(j)
    return q


def _ids(jobs):
    return [j.job_id for j in jobs]


# ----------------------------------------------------- backfill window

def test_backfill_queue_shorter_than_depth_keeps_order():
    q = _queue(_job("a"), _job("b"), _job("c"))
    got = Scheduler("backfill", depth=14).candidates(q)
    assert _ids(got) == ["a", "b", "c"]


def test_backfill_head_that_fits_stays_first():
    # the head is a candidate like any other; backfill widens the
    # window, it never reorders past a placeable head
    q = _queue(_job("head", size=1), _job("tail", size=8))
    got = Scheduler("backfill", depth=2).candidates(q)
    assert _ids(got) == ["head", "tail"]


def test_backfill_empty_queue():
    assert Scheduler("backfill", depth=14).candidates(WaitQueue()) == []
    assert Scheduler("fifo").candidates(WaitQueue()) == []


def test_backfill_depth_one_degenerates_to_head():
    q = _queue(_job("a"), _job("b"))
    assert _ids(Scheduler("backfill", depth=1).candidates(q)) == ["a"]


def test_backfill_truncates_to_depth():
    q = _queue(*[_job(f"j{i}") for i in range(6)])
    got = Scheduler("backfill", depth=4).candidates(q)
    assert _ids(got) == ["j0", "j1", "j2", "j3"]


def test_fifo_examines_only_the_head():
    q = _queue(_job("a"), _job("b"))
    assert _ids(Scheduler("fifo").candidates(q)) == ["a"]


# ------------------------------------------------------ priority tiers

def test_priority_tier_orders_window_stably():
    q = _queue(_job("n1"), _job("hi1", tier=TIER_HIGH), _job("n2"),
               _job("hi2", tier=TIER_HIGH))
    got = Scheduler("backfill", depth=4).candidates(q)
    # tier 0 first; submission order preserved within each tier
    assert _ids(got) == ["hi1", "hi2", "n1", "n2"]


def test_priority_tier_jumps_fifo_head():
    q = _queue(_job("n1"), _job("hi", tier=TIER_HIGH))
    assert _ids(Scheduler("fifo").candidates(q)) == ["hi"]


def test_all_default_tiers_preserve_submission_order():
    jobs = [_job(f"j{i}") for i in range(5)]
    q = _queue(*jobs)
    got = Scheduler("backfill", depth=8).candidates(q)
    assert got == jobs                        # identical objects, order


# ------------------------------------------------------------- quotas

def test_quota_filters_only_with_usage():
    sched = Scheduler("backfill", depth=8, quotas={"beta": 4})
    q = _queue(_job("a", size=4, tenant="beta"),
               _job("b", size=2, tenant="beta"),
               _job("c", size=2, tenant="acme"))
    # no usage supplied: replay paths see the unfiltered queue
    assert _ids(sched.candidates(q)) == ["a", "b", "c"]
    # beta already holds 2 of its 4: only the size-2 beta job fits
    assert _ids(sched.candidates(q, usage={"beta": 2})) == ["b", "c"]
    # at quota: beta disappears entirely
    assert _ids(sched.candidates(q, usage={"beta": 4})) == ["c"]


def test_quota_unlisted_tenant_unrestricted():
    sched = Scheduler("fifo", quotas={"beta": 2})
    q = _queue(_job("a", size=8, tenant="acme"))
    assert _ids(sched.candidates(q, usage={"acme": 100})) == ["a"]
    assert sched.admissible(_job("x", size=2, tenant="beta"),
                            {"beta": 1}) is False
    assert sched.admissible(_job("x", size=2, tenant="beta"),
                            {}) is True


def test_no_quotas_ignores_usage():
    sched = Scheduler("backfill", depth=8)
    q = _queue(_job("a", size=8, tenant="beta"))
    assert _ids(sched.candidates(q, usage={"beta": 999})) == ["a"]
