"""Per-arch smoke tests (deliverable f): reduced same-family config, one
forward/train step on CPU, output shapes + no NaNs."""
import jax
import jax.numpy as jnp
import pytest

from repro.models.registry import (ARCH_IDS, build_model, get_config,
                                   reduced_config)


def _batch(cfg, rng, B=2, S=32):
    batch = {
        "tokens": jax.random.randint(rng, (B, S), 0, cfg.vocab_size),
        "targets": jax.random.randint(rng, (B, S), 0, cfg.vocab_size),
    }
    if cfg.frontend == "patch":
        batch["media"] = jnp.ones((B, cfg.n_media_tokens, cfg.d_model),
                                  jnp.bfloat16)
    if cfg.frontend == "audio":
        batch["frames"] = jnp.ones((B, cfg.enc_seq_len, cfg.d_model),
                                   jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_train_step(arch):
    cfg = reduced_config(get_config(arch))
    model = build_model(cfg, remat=False)
    rng = jax.random.key(0)
    params = model.init(rng)
    batch = _batch(cfg, rng)

    logits, aux = jax.jit(model.forward_logits)(params, batch)
    assert logits.shape == (2, 32, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))

    # one SGD-flavoured train step: loss + grads finite, params update
    def loss_fn(p):
        return model.loss(p, batch)[0]
    loss, grads = jax.jit(jax.value_and_grad(loss_fn))(params)
    assert bool(jnp.isfinite(loss))
    gnorm = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                for g in jax.tree.leaves(grads))
    assert bool(jnp.isfinite(gnorm)) and float(gnorm) > 0.0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_axes_trees_match(arch):
    cfg = reduced_config(get_config(arch))
    model = build_model(cfg)
    params = jax.eval_shape(model.init, jax.random.key(0))
    axes = model.param_logical_axes()
    td_p = jax.tree.structure(params)
    td_a = jax.tree.structure(axes, is_leaf=lambda v: isinstance(v, tuple))
    assert td_p == td_a, f"{arch}: param/axes tree mismatch"
    # every axes tuple is no longer than the param rank
    flat_p = jax.tree.leaves(params)
    flat_a = jax.tree.leaves(axes,
                             is_leaf=lambda v: isinstance(v, tuple))
    for p, a in zip(flat_p, flat_a):
        assert len(a) == len(p.shape), f"{arch}: {a} vs {p.shape}"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_decode_step(arch):
    cfg = reduced_config(get_config(arch))
    model = build_model(cfg, remat=False)
    rng = jax.random.key(1)
    params = model.init(rng)
    B, S = 2, 16
    cache = model.init_cache(B, S)
    tokens = jax.random.randint(rng, (B, 1), 0, cfg.vocab_size)
    logits, cache2 = jax.jit(model.decode_step)(
        params, cache, tokens, jnp.int32(0))
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    assert jax.tree.structure(cache) == jax.tree.structure(cache2)


def test_full_configs_match_assignment():
    """Exact published dims for every assigned arch."""
    expect = {
        "whisper-tiny": (4, 384, 6, 6, 1536, 51865),
        "llama-3.2-vision-90b": (100, 8192, 64, 8, 28672, 128256),
        "command-r-plus-104b": (64, 12288, 96, 8, 33792, 256000),
        "glm4-9b": (40, 4096, 32, 2, 13696, 151552),
        "stablelm-1.6b": (24, 2048, 32, 32, 5632, 100352),
        "llama3.2-1b": (16, 2048, 32, 8, 8192, 128256),
        "qwen2-moe-a2.7b": (24, 2048, 16, 16, 0, 151936),
        "deepseek-v2-lite-16b": (27, 2048, 16, 16, 0, 102400),
        "zamba2-1.2b": (38, 2048, 32, 32, 8192, 32000),
        "xlstm-125m": (12, 768, 4, 4, 0, 50304),
    }
    for arch, (L, d, h, kv, ff, v) in expect.items():
        cfg = get_config(arch)
        assert cfg.n_layers == L, arch
        assert cfg.d_model == d, arch
        assert cfg.n_heads == h and cfg.n_kv_heads == kv, arch
        assert cfg.d_ff == ff, arch
        assert cfg.vocab_size == v, arch
    q = get_config("qwen2-moe-a2.7b").moe
    assert (q.n_routed, q.n_shared, q.top_k, q.d_ff) == (60, 4, 4, 1408)
    dv = get_config("deepseek-v2-lite-16b")
    assert dv.mla.kv_lora_rank == 512
    assert dv.moe.top_k == 6
    z = get_config("zamba2-1.2b")
    assert z.ssm.d_state == 64 and z.sub_quadratic
    assert get_config("xlstm-125m").sub_quadratic
