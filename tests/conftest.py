import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")


def run_multidevice(code: str, n_devices: int = 8,
                    timeout: int = 560) -> str:
    """Run ``code`` in a subprocess with fake host devices.

    XLA device count is locked at first jax init, so multi-device tests
    must run out of process (the main test process stays at 1 device).
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count="
                        f"{n_devices}")
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    res = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=timeout, env=env)
    if res.returncode != 0:
        raise AssertionError(
            f"multidevice subprocess failed:\n--- stdout ---\n"
            f"{res.stdout}\n--- stderr ---\n{res.stderr[-4000:]}")
    return res.stdout


@pytest.fixture(scope="session")
def repo_root():
    return REPO
