"""Dry-run machinery integration: lower+compile a real cell on a small
fake-device mesh and check the artifact contents end-to-end."""
import json

from tests.conftest import run_multidevice


def test_dryrun_cell_on_small_mesh():
    out = run_multidevice("""
        import os, json, tempfile
        # shrink the production mesh so the cell fits 8 fake devices
        import repro.launch.mesh as M
        import jax
        def small_mesh(*, multi_pod=False):
            if multi_pod:
                return jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
            return jax.make_mesh((2, 4), ("data", "model"))
        M.make_production_mesh = small_mesh
        import repro.launch.dryrun as D
        D.make_production_mesh = small_mesh

        d = tempfile.mkdtemp()
        for mp in (False, True):
            meta = D.run_cell("llama3.2-1b", "train_4k", multi_pod=mp,
                              out_dir=d)
            assert meta["status"] == "ok", meta.get("error")
            assert meta["roofline"]["bound_s"] > 0
            assert meta["hlo"]["dot_flops_per_device"] > 0
            assert meta["hlo"]["collective_bytes_per_device"] > 0
            assert meta["memory"]["temp_bytes"] > 0
            if mp:
                assert meta["mesh"] == "2x16x16"  # label, mesh shrunk
        # knobs lower too (the §Perf iteration paths)
        meta = D.run_cell("llama3.2-1b", "train_4k", multi_pod=False,
                          seq_parallel=True, fsdp=False,
                          accum_override=1, use_master=False, out_dir=d)
        assert meta["status"] == "ok", meta.get("error")
        assert meta["knobs"]["seq_parallel"] is True
        # decode + skip cells
        meta = D.run_cell("llama3.2-1b", "decode_32k", multi_pod=False)
        assert meta["status"] == "ok", meta.get("error")
        meta = D.run_cell("llama3.2-1b", "long_500k", multi_pod=False)
        assert meta["status"] == "skipped"
        print("DRYRUN_OK")
        """, n_devices=8, timeout=540)
    assert "DRYRUN_OK" in out


def test_artifacts_complete_if_present(repo_root):
    """When the full sweep artifacts exist, assert the 40-cell coverage
    contract: every runnable cell ok on both meshes, skips documented."""
    import glob
    import os
    art = os.path.join(repo_root, "artifacts", "dryrun")
    files = [f for f in glob.glob(os.path.join(art, "*.json"))
             if len(os.path.basename(f)[:-5].split("__")) == 3]
    if len(files) < 80:
        import pytest
        pytest.skip("full sweep artifacts not present")
    by_status = {}
    for fn in files:
        with open(fn) as f:
            meta = json.load(f)
        by_status.setdefault(meta.get("status"), []).append(
            (meta["arch"], meta["shape"], meta["mesh"]))
    assert not by_status.get("error"), by_status.get("error")
    assert len(by_status.get("ok", [])) == 64
    skipped = by_status.get("skipped", [])
    assert len(skipped) == 16
    assert all(s[1] == "long_500k" for s in skipped)
