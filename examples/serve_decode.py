"""Batched serving demo: continuous batching over decode steps with KV
caches (the decode_32k dry-run path at toy scale).

Run:  PYTHONPATH=src python examples/serve_decode.py
"""
import numpy as np
import jax

from repro.models.registry import build_model, get_config, reduced_config
from repro.serve import BatchedServer, Request


def main():
    cfg = reduced_config(get_config("llama3.2-1b"))
    model = build_model(cfg, remat=False)
    params = model.init(jax.random.key(0))
    server = BatchedServer(model, params, max_batch=4, max_seq=64)

    rng = np.random.default_rng(0)
    for rid in range(6):
        prompt = rng.integers(1, cfg.vocab_size,
                              size=rng.integers(3, 8)).astype(np.int32)
        server.submit(Request(rid, prompt, max_new=8))
        print(f"submitted request {rid}: prompt={prompt.tolist()}")

    server.run_until_drained()
    for req in sorted(server.completed, key=lambda r: r.rid):
        print(f"request {req.rid}: generated {req.out}")
    assert len(server.completed) == 6
    print("all requests served")


if __name__ == "__main__":
    main()
