"""Cluster-scale scheduling demo: replay a synthetic trace under all three
operation modes and print the paper's headline comparison — including a
scale-out run (64 hosts / 128 GPUs) showing the policy holds beyond the
2-GPU testbed.

Run:  PYTHONPATH=src python examples/cluster_sim.py
"""
from repro.core.metrics import ModeComparison
from repro.core.simulator import simulate
from repro.core.traces import TraceCategory, generate_trace


def show(title, jobs, modes=("FM", "DM", "SM"), **kw):
    print(f"\n=== {title} ({len(jobs)} jobs) ===")
    results = {}
    for mode in modes:
        r = simulate(jobs, mode, **kw)
        results[mode] = r
        print(f"  {mode}: makespan={r.makespan/3600:6.2f}h "
              f"jct={r.avg_jct/60:6.1f}min wait={r.avg_wait/60:6.1f}min "
              f"util={r.utilization:.2f} reconfigs={r.n_reconfigs}")
    if "DM" in results:
        c = ModeComparison.of(results["FM"], results["DM"])
        print(f"  FM/DM: makespan={c.makespan_ratio:.3f} "
              f"wait={c.wait_ratio:.3f} jct={c.jct_ratio:.3f}")
    return results


def main():
    # paper testbed scale: 1 host, 2 A100s
    jobs = generate_trace(
        TraceCategory("helios_earth", "large", "train"),
        seed=0, double=True, max_size=4)
    show("paper testbed, train-only, FIFO", jobs)

    jobs = generate_trace(
        TraceCategory("philly", "small", "mixed"), seed=1, double=True)
    show("paper testbed, mixed, backfilling", jobs, modes=("FM", "DM"),
         policy="backfill")

    # scale-out: 64 hosts x 2 GPUs, 10x the jobs, tighter arrivals
    big = []
    for seed in range(10):
        big.extend(generate_trace(
            TraceCategory("alibaba", "balanced", "mixed"),
            seed=seed, double=True, mean_interarrival=3.0))
    for i, j in enumerate(big):
        j.job_id = f"j{i:05d}"
    show("scale-out: 64 hosts / 128 GPUs / 896 leaves", big,
         modes=("FM", "DM"), policy="backfill", n_hosts=64)


if __name__ == "__main__":
    main()
