"""End-to-end driver: train a ~125M-param LM (xlstm-125m, the assigned
arch) for a few hundred steps with checkpointing and fault recovery.

By default runs a width-reduced config so a few hundred steps finish on
the CPU container; pass --full to train the exact assigned 125M config
(slow on CPU, the real target is the TPU mesh via repro.launch.train).

Run:  PYTHONPATH=src python examples/train_lm.py --steps 300
"""
import argparse

from repro import optim
from repro.data import DataConfig
from repro.models.registry import build_model, get_config, reduced_config
from repro.train import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--arch", default="xlstm-125m")
    ap.add_argument("--full", action="store_true",
                    help="train the full assigned config (slow on CPU)")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/train_lm_ckpt")
    ap.add_argument("--inject-failure-at", type=int, default=-1)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full:
        cfg = reduced_config(cfg)
    model = build_model(cfg, remat=False)
    n_params = sum(p.size for p in __import__("jax").tree.leaves(
        __import__("jax").eval_shape(model.init,
                                     __import__("jax").random.key(0))))
    print(f"arch={args.arch} params={n_params/1e6:.1f}M "
          f"steps={args.steps}")

    failure = None
    if args.inject_failure_at >= 0:
        failure = lambda s: s == args.inject_failure_at  # noqa: E731

    trainer = Trainer(
        model,
        optim.AdamWConfig(peak_lr=3e-4, warmup_steps=20,
                          total_steps=args.steps),
        TrainerConfig(n_steps=args.steps, ckpt_every=100,
                      ckpt_dir=args.ckpt_dir, log_every=20),
        DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                   global_batch=args.batch),
        failure_hook=failure)
    try:
        out = trainer.run(resume=True)
    except RuntimeError as e:
        print(f"failure: {e}; restarting from checkpoint ...")
        trainer2 = Trainer(
            model, optim.AdamWConfig(peak_lr=3e-4, warmup_steps=20,
                                     total_steps=args.steps),
            TrainerConfig(n_steps=args.steps, ckpt_every=100,
                          ckpt_dir=args.ckpt_dir, log_every=20),
            DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                       global_batch=args.batch))
        out = trainer2.run(resume=True)
    for h in out["history"]:
        print(f"step {h['step']:4d}  loss {h['loss']:.4f}  "
              f"{h['sec_per_step']*1e3:.0f} ms/step")
    print("straggler summary:", out["stragglers"])


if __name__ == "__main__":
    main()
