"""Quickstart: the Flex-MIG pipeline in 60 lines.

1. Partition a 2-GPU host into fixed minimal leaves (one-to-many setup).
2. Schedule a size-4 training job across both GPUs (policy §3.2).
3. Launch it: MIG-aware peer discovery + synthetic bus-ID labeling form
   the communicator over SHM (the paper's §4.2 runtime fix).
4. Train a tiny LM for a few steps on the aggregated leaves (CPU demo).

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.core.executor import JobExecutor
from repro.core.job import Job
from repro.core.leaves import Cluster
from repro.core.modes import FlexMIG
from repro import optim
from repro.data import DataConfig
from repro.models.registry import build_model, get_config, reduced_config
from repro.train import Trainer, TrainerConfig


def main():
    # --- orchestration layer ---------------------------------------
    cluster = Cluster(n_hosts=1, gpus_per_host=2)
    fm = FlexMIG()
    fm.setup(cluster)
    print(f"leaf pool: {cluster.total_leaves()} instances "
          f"(6x1g.5gb + 1x1g.10gb per GPU)")

    job = Job("demo", "bert-base", "train", size=4, batch=32,
              base_duration=600.0)
    placement = fm.try_place(job, cluster)
    print(f"placed size-{job.size} job on "
          f"{[i.uuid for i in placement.instances]} "
          f"(leaves/GPU={placement.leaves_per_gpu()}, "
          f"transport={placement.transport})")

    # --- runtime layer ----------------------------------------------
    launched = JobExecutor().launch(job, placement, mig_aware=True)
    print(f"communicator formed: {launched.pod.n_workers} ranks, "
          f"transports={sorted(set(launched.transports.values()))}")

    # --- the distributed work itself (tiny LM, CPU) ------------------
    cfg = reduced_config(get_config("llama3.2-1b"))
    model = build_model(cfg, remat=False)
    trainer = Trainer(
        model,
        optim.AdamWConfig(peak_lr=1e-3, warmup_steps=5, total_steps=30),
        TrainerConfig(n_steps=20, ckpt_every=10, log_every=5,
                      ckpt_dir="/tmp/quickstart_ckpt"),
        DataConfig(vocab_size=cfg.vocab_size, seq_len=64,
                   global_batch=len(placement.instances)))
    out = trainer.run(resume=False)
    for h in out["history"]:
        print(f"step {h['step']:3d}  loss {h['loss']:.3f}")
    print("done — job leaves released")
    fm.release(placement, cluster)


if __name__ == "__main__":
    main()
